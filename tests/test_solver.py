"""Krylov solvers: convergence, preconditioners, format-agnostic matvec."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (COODevice, EHYBDevice, PRECONDITIONERS, bicgstab,
                        build_ehyb, cg, coo_spmv, ehyb_spmv, poisson3d,
                        unstructured)


@pytest.mark.parametrize("pc", ["none", "jacobi", "spai"])
def test_cg_converges_all_preconditioners(pc, rng):
    m = poisson3d(8)
    dev = EHYBDevice.from_ehyb(build_ehyb(m))
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    r = cg(lambda v: ehyb_spmv(dev, v), b, PRECONDITIONERS[pc](m),
           tol=1e-5, max_iters=1000)
    assert bool(r.converged), (pc, float(r.residual))
    # residual check against the true operator
    ax = m.spmv(np.asarray(r.x, dtype=np.float64))
    rel = np.linalg.norm(ax - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert rel < 1e-4


def test_bicgstab_nonsymmetric(rng):
    m = unstructured(512, 10, seed=9)      # slightly non-symmetric values
    dev = COODevice.from_csr(m)
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    r = bicgstab(lambda v: coo_spmv(dev, v), b,
                 PRECONDITIONERS["jacobi"](m), tol=1e-5, max_iters=1000)
    assert bool(r.converged)


def test_matvec_format_agnostic(rng):
    """Same Krylov trajectory whatever the SpMV backend (paper's experiment:
    swap the kernel, keep the solver)."""
    m = poisson3d(6)
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    dev_e = EHYBDevice.from_ehyb(build_ehyb(m))
    dev_c = COODevice.from_csr(m)
    r1 = cg(lambda v: ehyb_spmv(dev_e, v), b, tol=1e-6, max_iters=500)
    r2 = cg(lambda v: coo_spmv(dev_c, v), b, tol=1e-6, max_iters=500)
    assert int(r1.iters) == int(r2.iters)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               rtol=1e-3, atol=1e-4)
