"""Krylov solvers: convergence, preconditioners, format-agnostic matvec."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (COODevice, EHYBDevice, PRECONDITIONERS, bicgstab,
                        build_ehyb, cg, coo_spmv, ehyb_spmv, poisson3d,
                        unstructured)


@pytest.mark.parametrize("pc", ["none", "jacobi", "spai"])
def test_cg_converges_all_preconditioners(pc, rng):
    m = poisson3d(8)
    dev = EHYBDevice.from_ehyb(build_ehyb(m))
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    r = cg(lambda v: ehyb_spmv(dev, v), b, PRECONDITIONERS[pc](m),
           tol=1e-5, max_iters=1000)
    assert bool(r.converged), (pc, float(r.residual))
    # residual check against the true operator
    ax = m.spmv(np.asarray(r.x, dtype=np.float64))
    rel = np.linalg.norm(ax - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert rel < 1e-4


def test_bicgstab_nonsymmetric(rng):
    m = unstructured(512, 10, seed=9)      # slightly non-symmetric values
    dev = COODevice.from_csr(m)
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    r = bicgstab(lambda v: coo_spmv(dev, v), b,
                 PRECONDITIONERS["jacobi"](m), tol=1e-5, max_iters=1000)
    assert bool(r.converged)


def test_matvec_format_agnostic(rng):
    """Same Krylov trajectory whatever the SpMV backend (paper's experiment:
    swap the kernel, keep the solver)."""
    m = poisson3d(6)
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    dev_e = EHYBDevice.from_ehyb(build_ehyb(m))
    dev_c = COODevice.from_csr(m)
    r1 = cg(lambda v: ehyb_spmv(dev_e, v), b, tol=1e-6, max_iters=500)
    r2 = cg(lambda v: coo_spmv(dev_c, v), b, tol=1e-6, max_iters=500)
    assert int(r1.iters) == int(r2.iters)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# warm starts (ISSUE 5 satellite: solve() used to ignore any initial guess)
# ---------------------------------------------------------------------------

def test_warm_started_cg_converges_in_fewer_iterations(rng):
    """Regression: ``solve`` accepts ``x0=`` and permutes it once into the
    execution space alongside ``b`` — a warm start from (near) the solution
    must beat the cold start's iteration count, at the same tolerance."""
    from repro import api

    m = poisson3d(8)
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    op = api.plan(m, execution=api.ExecutionConfig(
        format="ehyb", workload="solver")).bind(m)
    cold = op.solve(b, tol=1e-6, max_iters=800)
    assert bool(cold.converged) and int(cold.iters) > 3
    warm = op.solve(b, x0=cold.x, tol=1e-6, max_iters=800)
    assert bool(warm.converged)
    assert int(warm.iters) < int(cold.iters)
    # a partially converged iterate also warm starts (the transient-FEM
    # shape: consecutive systems share a nearby solution)
    part = op.solve(b, tol=1e-2, max_iters=800)
    warm2 = op.solve(b, x0=part.x, tol=1e-6, max_iters=800)
    assert int(warm2.iters) < int(cold.iters)
    np.testing.assert_allclose(np.asarray(warm2.x), np.asarray(cold.x),
                               rtol=1e-3, atol=1e-4)


def test_warm_start_through_deprecated_solve_and_bicgstab(rng):
    import warnings

    from repro.core import solve

    m = poisson3d(6)
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cold = solve(m, b, method="bicgstab", tol=1e-6, max_iters=800)
        warm = solve(m, b, method="bicgstab", x0=cold.x, tol=1e-6,
                     max_iters=800)
    assert bool(cold.converged) and bool(warm.converged)
    assert int(warm.iters) < int(cold.iters)


def test_warm_start_distributed_solve(rng):
    from repro import api
    from repro.compat import make_mesh

    m = poisson3d(6)
    b = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    op = api.plan(m, mesh=make_mesh((1,), ("data",))).bind(m)
    cold = op.solve(b, tol=1e-6, max_iters=600)
    warm = op.solve(b, x0=cold.x, tol=1e-6, max_iters=600)
    assert bool(warm.converged)
    assert int(warm.iters) < int(cold.iters)
