"""Training substrate: loss descent, microbatch-accumulation equivalence,
optimizer numerics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticTokenDataset
from repro.models import init_model
from repro.train import (OptimizerConfig, init_train_state, make_train_step)


def tiny_cfg():
    return get_config("llama3_2_1b", smoke=True)


def make_batch(cfg, b=4, s=64, step=0):
    ds = SyntheticTokenDataset(cfg.vocab_size, s, b, seed=7)
    return {k: jnp.asarray(v) for k, v in ds.train_inputs(step).items()}


def test_loss_decreases():
    cfg = tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, cfg)
    step = jax.jit(make_train_step(
        cfg, OptimizerConfig(lr=1e-2, warmup_steps=2, total_steps=40)))
    batch = make_batch(cfg)
    losses = []
    for _ in range(15):                    # overfit one batch
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_equals_full_batch_grads():
    """Grad accumulation must average to the same update (linearity)."""
    cfg = tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = OptimizerConfig(lr=1e-3, total_steps=10)
    s1 = init_train_state(params, cfg)
    s2 = init_train_state(params, cfg)
    batch = make_batch(cfg, b=4)
    st1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(s1, batch)
    st2, m2 = jax.jit(make_train_step(cfg, opt, microbatches=2))(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     st1.params, st2.params)
    assert max(jax.tree.leaves(d)) < 5e-4


def test_bf16_optimizer_state():
    cfg = dataclasses.replace(tiny_cfg(), opt_state_dtype="bfloat16")
    params = init_model(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, cfg)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state.opt.m))
    step = jax.jit(make_train_step(cfg, OptimizerConfig(total_steps=10)))
    state, metrics = step(state, make_batch(cfg))
    assert jnp.isfinite(metrics["loss"])


def test_grad_clipping_bounds_update():
    from repro.train import clip_by_global_norm, global_norm

    g = {"a": jnp.full((8, 8), 100.0), "b": jnp.full((4,), -50.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert float(global_norm(clipped)) <= 1.0 + 1e-5


def test_lr_schedule_shape():
    from repro.train import lr_at

    opt = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_at(opt, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] < lrs[1] < lrs[2]        # warmup
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[2] > lrs[3] > lrs[4]        # cosine decay
    assert lrs[4] == pytest.approx(0.1, abs=1e-3)
