"""Pallas kernel sweeps: shapes × dtypes, allclose vs the ref.py jnp oracle
(interpret mode executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EHYBDevice, build_ehyb, ehyb_spmv, poisson3d, unstructured
from repro.kernels import (ehyb_ell_pallas, ehyb_spmv_pallas, er_pallas, ref)


def _rand_ell(rng, p, v, w, r, dtype):
    x_parts = rng.standard_normal((p, v, r)).astype(dtype)
    vals = (rng.standard_normal((p, v, w)) *
            (rng.random((p, v, w)) < 0.7)).astype(dtype)
    cols = rng.integers(0, v, size=(p, v, w)).astype(np.uint16)
    return jnp.asarray(x_parts), jnp.asarray(vals), jnp.asarray(cols)


@pytest.mark.parametrize("v,w,r", [(8, 1, 1), (64, 3, 1), (64, 17, 4),
                                   (512, 7, 1), (128, 33, 2)])
@pytest.mark.parametrize("dtype,tol", [(np.float32, 2e-5), ("bfloat16", 3e-2)])
def test_ell_kernel_sweep(v, w, r, dtype, tol, rng):
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    x, vals, cols = _rand_ell(rng, 4, v, w, r, np.float32)
    x, vals = x.astype(dt), vals.astype(dt)
    out = ehyb_ell_pallas(x, vals, cols, interpret=True)
    expect = ref.ehyb_ell_ref(x.astype(jnp.float32),
                              vals.astype(jnp.float32), cols)
    scale = float(jnp.max(jnp.abs(expect))) + 1e-30
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - expect))) / scale
    assert err < tol, (v, w, r, dtype, err)


@pytest.mark.parametrize("rows,w,r", [(8, 1, 1), (64, 9, 1), (256, 5, 4)])
def test_er_kernel_sweep(rows, w, r, rng):
    n_pad = 512
    x = jnp.asarray(rng.standard_normal((n_pad, r)), dtype=jnp.float32)
    vals = jnp.asarray(rng.standard_normal((rows, w)), dtype=jnp.float32)
    cols = jnp.asarray(rng.integers(0, n_pad, (rows, w)), dtype=jnp.int32)
    out = er_pallas(x, vals, cols, interpret=True)
    expect = ref.er_ref(x, vals, cols)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("gen", [lambda: poisson3d(8),
                                 lambda: unstructured(1024, 12)])
@pytest.mark.parametrize("use_er_kernel", [True, False])
def test_full_kernel_vs_jnp_path(gen, use_er_kernel, rng):
    m = gen()
    dev = EHYBDevice.from_ehyb(build_ehyb(m))
    x = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    y_k = np.asarray(ehyb_spmv_pallas(dev, x, interpret=True,
                                      use_er_kernel=use_er_kernel))
    y_j = np.asarray(ehyb_spmv(dev, x))
    np.testing.assert_allclose(y_k, y_j, rtol=1e-4, atol=1e-4)
    y_ref = m.spmv(np.asarray(x, dtype=np.float64))
    np.testing.assert_allclose(y_k, y_ref, atol=1e-4 * np.abs(y_ref).max())


def test_kernel_spmm(rng):
    m = poisson3d(8)
    dev = EHYBDevice.from_ehyb(build_ehyb(m))
    xs = jnp.asarray(rng.standard_normal((m.n, 4)), dtype=jnp.float32)
    y = np.asarray(ehyb_spmv_pallas(dev, xs, interpret=True))
    np.testing.assert_allclose(y, np.asarray(ehyb_spmv(dev, xs)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("gen", [lambda: poisson3d(8),
                                 lambda: unstructured(1024, 12)])
def test_packed_kernel_v2(gen, rng):
    """Kernel v2 (packed staircase) == v1 == oracle, and strictly fewer
    modeled HBM bytes on irregular matrices."""
    from repro.core import EHYBPackedDevice, pack_staircase
    from repro.kernels import ehyb_spmv_packed_pallas

    m = gen()
    e = build_ehyb(m)
    pk = pack_staircase(e)
    dev2 = EHYBPackedDevice.from_packed(pk)
    x = jnp.asarray(rng.standard_normal(m.n), dtype=jnp.float32)
    y2 = np.asarray(ehyb_spmv_packed_pallas(dev2, x, interpret=True))
    y_ref = m.spmv(np.asarray(x, dtype=np.float64))
    np.testing.assert_allclose(y2, y_ref, atol=1e-4 * np.abs(y_ref).max())
    assert (pk.bytes_moved(4)["total"]
            <= e.bytes_moved(4, layout="tile")["total"] + 8 * m.n)
