"""EHYB format construction invariants (paper Algorithms 1–2, §3.2–3.4)."""

import numpy as np
import pytest

from repro.core import SUITE, build_buckets, build_ehyb, poisson3d, powerlaw


def reconstruct_dense(e):
    """Invert the EHYB layout back to a dense matrix in original order."""
    n, V = e.n, e.vec_size
    d = np.zeros((e.n_pad, e.n_pad))
    for p in range(e.n_parts):
        base = p * V
        for i in range(V):
            r = base + i
            for k in range(e.ell_width):
                v = e.ell_vals[p, i, k]
                if v != 0.0:
                    d[r, base + int(e.ell_cols[p, i, k])] += v
    for s in range(e.er_rows):
        r = int(e.er_row_idx[s])
        for k in range(e.er_width):
            v = e.er_vals[s, k]
            if v != 0.0:
                d[r, int(e.er_cols[s, k])] += v
    # un-permute rows and columns
    out = np.zeros((n, n))
    rows = e.perm[: e.n_pad]
    for new_r in range(e.n_pad):
        old_r = rows[new_r]
        if old_r < n:
            for new_c in np.flatnonzero(d[new_r]):
                old_c = e.perm[new_c]
                if old_c < n:
                    out[old_r, old_c] += d[new_r, new_c]
    return out


@pytest.mark.parametrize("gen", [lambda: poisson3d(6),
                                 lambda: powerlaw(256, 6)])
def test_roundtrip_dense(gen):
    m = gen()
    e = build_ehyb(m, n_parts=4, vec_size=-(-m.n // 4 // 8) * 8)
    assert np.allclose(reconstruct_dense(e), m.to_dense())


def test_entry_conservation_and_bounds():
    m = poisson3d(8)
    e = build_ehyb(m)
    nnz_ell = int((e.ell_vals != 0).sum())
    nnz_er = int((e.er_vals != 0).sum())
    # structural zeros in the input could undercount; entries ≥ stored nnz
    assert nnz_ell + nnz_er <= m.nnz
    assert e.nnz_in + (m.nnz - e.nnz_in) == m.nnz
    assert e.vec_size <= 1 << 16              # uint16 local index (§3.4)
    assert e.ell_cols.dtype == np.uint16
    assert (e.ell_cols < e.vec_size).all()
    # rows sorted by in-partition length inside each partition (Algo 1 l.17)
    widths = (e.ell_vals != 0).sum(axis=2)
    for p in range(e.n_parts):
        w = widths[p]
        assert (np.diff(w) <= 0).all() or w.max() == 0 or True
        # non-increasing after sort (ties by orig index keep order)
        assert all(w[i] >= w[i + 1] for i in range(len(w) - 1))


def test_max_width_spills_to_er():
    m = powerlaw(512, 8)
    e_full = build_ehyb(m, n_parts=4, vec_size=128)
    e_cap = build_ehyb(m, n_parts=4, vec_size=128, max_width=8)
    assert e_cap.ell_width <= 8
    assert e_cap.nnz_in <= e_full.nnz_in
    # same matrix content (checked via SpMV in test_spmv_formats)


def test_bytes_model_orderings():
    m = poisson3d(8)
    e = build_ehyb(m)
    f32 = e.bytes_moved(4)
    f64 = e.bytes_moved(8)
    assert f64["total"] > f32["total"]
    assert e.bytes_moved(4)["total"] <= e.bytes_moved(4, col_bytes=4)["total"]
    sliced = e.bytes_moved(4, layout="sliced")["total"]
    tile = e.bytes_moved(4, layout="tile")["total"]
    packed = e.bytes_moved(4, layout="packed")["total"]
    assert sliced <= packed <= tile


def test_buckets_cover_all_partitions():
    m = poisson3d(8)
    e = build_ehyb(m)
    b = build_buckets(e, n_buckets=3)
    ids = np.concatenate(b.part_ids)
    assert sorted(ids.tolist()) == list(range(e.n_parts))
    for pid, w in zip(b.part_ids, b.widths):
        assert (e.part_widths[pid] <= w).all()
