"""Value-refresh fast path: same pattern + new values must refill — not
rebuild, not recompile — and match a from-scratch build bit-for-bit.

Also the operator-reuse bugfix regressions that ride along:
  * measured autotuning with ``context="solver"`` times the permuted-space
    apply (not the original-space one whose per-call perm round trip
    pollutes solver-ranked timings);
  * the diagonal-preconditioner closure carries fp64 solves at fp64;
  * ``matrix_key`` distinguishes value buffers with identical bytes but
    different dtypes;
  * an integer rhs never builds integer value tables.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import autotune as at
from repro.core import build_ehyb, build_spmv, poisson3d, powerlaw, solve, spmv
from repro.core import counters
from repro.core.matrices import SparseCSR
from repro.core.solver import _diag_closure


def _with_new_values(m: SparseCSR, seed: int = 7) -> SparseCSR:
    data = np.random.default_rng(seed).standard_normal(m.nnz)
    return SparseCSR(m.n, m.indptr, m.indices, data)


STRUCTURE_COUNTERS = ("partition", "build_ehyb", "pack_staircase",
                      "build_buckets")


def _structure_work(before: dict, after: dict) -> dict:
    return {c: after.get(c, 0) - before.get(c, 0) for c in STRUCTURE_COUNTERS
            if after.get(c, 0) != before.get(c, 0)}


# ---------------------------------------------------------------------------
# refill equivalence: every format × fp32/fp64, bit-identical device tables
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", sorted(at.available_formats()))
@pytest.mark.parametrize("dtype_name", ["float32", "float64"])
@pytest.mark.parametrize("gen", ["stencil", "powerlaw"])
def test_refill_matches_fresh_build_bit_identical(fmt, dtype_name, gen):
    m1 = poisson3d(6) if gen == "stencil" else powerlaw(256, 4)
    m2 = _with_new_values(m1)
    with jax.experimental.enable_x64(dtype_name == "float64"):
        dtype = jnp.dtype(dtype_name)
        op1 = build_spmv(m1, fmt, dtype)
        op2 = op1.update_values(m2)
        # fresh from-scratch build (shared dict pins a scratch host EHYB so
        # the global pattern cache cannot itself serve a refill here)
        fresh = build_spmv(m2, fmt, dtype, shared={"ehyb": build_ehyb(m2)})
        l_refill = jax.tree_util.tree_leaves(op2.obj)
        l_fresh = jax.tree_util.tree_leaves(fresh.obj)
        assert len(l_refill) == len(l_fresh)
        for a, b in zip(l_refill, l_fresh):
            assert a.dtype == b.dtype
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # refilled operator computes the new matrix
        if at.get_format(fmt).kernel == "xla":
            x = jnp.asarray(np.random.default_rng(0).standard_normal(m1.n),
                            dtype)
            y = np.asarray(op2(x), np.float64)
            y_ref = m2.spmv(np.asarray(x, np.float64))
            np.testing.assert_allclose(y, y_ref, rtol=5e-5, atol=5e-5)


def test_update_values_rejects_pattern_change():
    op = build_spmv(poisson3d(6), "csr")
    other = poisson3d(8)
    with pytest.raises(ValueError):
        op.update_values(other)


# ---------------------------------------------------------------------------
# amortization guarantees: zero structure passes, zero recompilation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["ehyb", "ehyb_bucketed", "ehyb_packed"])
def test_refill_triggers_zero_partitioning_or_packing(fmt):
    m1 = powerlaw(256, 4)
    m2 = _with_new_values(m1)
    op1 = build_spmv(m1, fmt)
    before = counters.snapshot()
    op2 = op1.update_values(m2)
    after = counters.snapshot()
    assert _structure_work(before, after) == {}
    assert after.get("ehyb_refill", 0) == before.get("ehyb_refill", 0) + 1
    # structural device arrays are shared by reference, not re-uploaded
    if fmt == "ehyb":
        assert op2.obj.ell_cols is op1.obj.ell_cols
        assert op2.obj.perm is op1.obj.perm
    elif fmt == "ehyb_packed":
        assert op2.obj.packed_cols is op1.obj.packed_cols
        assert op2.obj.col_starts is op1.obj.col_starts
    else:
        assert all(c2 is c1 for c1, c2 in zip(op1.obj.cols, op2.obj.cols))


def test_refill_never_calls_build_ehyb(monkeypatch):
    """Monkeypatch proof: the whole update path works with build_ehyb gone."""
    import repro.autotune.registry as registry
    import repro.core.ehyb as ehyb_mod

    m1 = poisson3d(6)
    m2 = _with_new_values(m1)
    op1 = build_spmv(m1, "ehyb")

    def boom(*a, **k):
        raise AssertionError("build_ehyb must not run on a value-only update")

    monkeypatch.setattr(registry, "build_ehyb", boom)
    monkeypatch.setattr(ehyb_mod, "build_ehyb", boom)
    op2 = op1.update_values(m2)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(m1.n),
                    jnp.float32)
    np.testing.assert_allclose(np.asarray(op2(x), np.float64),
                               m2.spmv(np.asarray(x, np.float64)),
                               rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("fmt", ["csr", "ehyb", "ehyb_bucketed"])
def test_refill_triggers_zero_recompilation(fmt):
    m1 = poisson3d(6)
    m2 = _with_new_values(m1)
    op1 = build_spmv(m1, fmt)
    jax.block_until_ready(op1(jnp.ones(m1.n, jnp.float32)))
    probe = getattr(op1.apply, "_cache_size", None)
    if probe is None:
        pytest.skip("jit cache-size probe unavailable on this jax")
    n0 = probe()
    op2 = op1.update_values(m2)
    jax.block_until_ready(op2(jnp.ones(m1.n, jnp.float32)))
    assert probe() == n0


def test_cached_spmv_operator_refills_on_value_only_change():
    m1 = poisson3d(6)
    m2 = _with_new_values(m1)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(m1.n),
                    jnp.float32)
    y1 = spmv(m1, x, format="ehyb")
    before = counters.snapshot()
    y2 = spmv(m2, x, format="ehyb")
    after = counters.snapshot()
    assert _structure_work(before, after) == {}
    assert after.get("ehyb_refill", 0) > before.get("ehyb_refill", 0)
    np.testing.assert_allclose(np.asarray(y2, np.float64),
                               m2.spmv(np.asarray(x, np.float64)),
                               rtol=5e-5, atol=5e-5)
    # and an exact repeat stays a pure cache hit (same operator object)
    from repro.core.spmv import cached_spmv_operator

    assert cached_spmv_operator(m2, "ehyb", jnp.float32) is \
        cached_spmv_operator(m2, "ehyb", jnp.float32)


def test_solve_reuses_structure_across_value_updates():
    """Transient-FEM shape: re-solve with updated values on a fixed pattern
    must not re-run the partition/reorder pipeline, and must see the new
    matrix (scaled A ⇒ scaled-down x)."""
    m1 = poisson3d(6)
    m2 = SparseCSR(m1.n, m1.indptr, m1.indices, m1.data * 2.0)
    b = jnp.asarray(np.random.default_rng(2).standard_normal(m1.n),
                    jnp.float32)
    r1 = solve(m1, b, tol=1e-8)
    before = counters.snapshot()
    r2 = solve(m2, b, tol=1e-8)
    after = counters.snapshot()
    assert _structure_work(before, after) == {}
    assert bool(r1.converged) and bool(r2.converged)
    np.testing.assert_allclose(np.asarray(r2.x), np.asarray(r1.x) / 2.0,
                               rtol=1e-4, atol=1e-5)


def test_sparse_linear_update_values_refills():
    from repro.core.sparse_linear import SparseLinear

    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((24, 48))
    lin = SparseLinear.from_dense(w1, density=0.25, format="ehyb")
    before = counters.snapshot()
    lin2 = lin.update_values(w1 * 3.0)
    after = counters.snapshot()
    assert _structure_work(before, after) == {}
    x = jnp.asarray(rng.standard_normal((2, 48)), jnp.float32)
    np.testing.assert_allclose(np.asarray(lin2(x)), 3.0 * np.asarray(lin(x)),
                               rtol=1e-4, atol=1e-4)
    assert lin2.ehyb is not None and lin2.op.obj.perm is lin.op.obj.perm


# ---------------------------------------------------------------------------
# bugfix regressions
# ---------------------------------------------------------------------------

def test_measured_solver_context_times_permuted_apply(monkeypatch):
    """autotune(mode="measure", context="solver") must time the operation
    the solver loop runs — the permuted-space apply on an (n_pad,) vector —
    not the original-space apply with its per-call perm round trip."""
    import repro.autotune.tuner as tuner

    calls = []

    def spy(apply, obj, x, **kw):
        calls.append((apply, obj, x))
        return 1.0

    monkeypatch.setattr(tuner, "_time_spmv", spy)
    m = poisson3d(8)
    at.autotune(m, mode="measure", context="solver",
                candidates=["ehyb", "csr"], top_k=2, use_cache=False)
    spec = at.get_format("ehyb")
    (apply_ehyb, obj_ehyb, x_ehyb), = [
        c for c in calls if hasattr(c[1], "n_pad")]
    assert apply_ehyb is spec.permuted    # not the original-space ehyb_spmv
    assert x_ehyb.shape[0] == obj_ehyb.n_pad   # permuted padded input
    # non-permuted formats still time the original-space apply on (n,)
    (apply_csr, _, x_csr), = [c for c in calls if not hasattr(c[1], "n_pad")]
    assert x_csr.shape[0] == m.n


def test_diag_precond_closure_preserves_fp64():
    inv = np.full(16, 0.5)
    with jax.experimental.enable_x64():
        r64 = jnp.ones(16, jnp.float64)
        assert _diag_closure(inv)(r64).dtype == jnp.float64
    r32 = jnp.ones(16, jnp.float32)
    assert _diag_closure(inv)(r32).dtype == jnp.float32


def test_fp64_solve_stays_fp64_end_to_end():
    m = poisson3d(6)
    with jax.experimental.enable_x64():
        b = jnp.asarray(np.random.default_rng(3).standard_normal(m.n),
                        jnp.float64)
        r = solve(m, b, precond="jacobi", format="csr", tol=1e-12,
                  max_iters=800)
        assert r.x.dtype == jnp.float64
        assert bool(r.converged)
        x_ref = np.linalg.solve(m.to_dense(), np.asarray(b))
        np.testing.assert_allclose(np.asarray(r.x), x_ref, rtol=1e-8,
                                   atol=1e-8)


def test_matrix_key_distinguishes_dtypes_with_identical_bytes():
    m = poisson3d(4)
    m_f32 = SparseCSR(m.n, m.indptr, m.indices, np.zeros(m.nnz, np.float32))
    m_i32 = SparseCSR(m.n, m.indptr, m.indices, np.zeros(m.nnz, np.int32))
    assert m_f32.data.tobytes() == m_i32.data.tobytes()
    assert at.matrix_key(m_f32) != at.matrix_key(m_i32)


def test_integer_rhs_promotes_to_float_operator():
    m = poisson3d(6)
    x_int = jnp.ones(m.n, jnp.int32)
    y = spmv(m, x_int, format="csr")
    assert jnp.issubdtype(y.dtype, jnp.floating)
    np.testing.assert_allclose(np.asarray(y, np.float64),
                               m.spmv(np.ones(m.n)), rtol=1e-5, atol=1e-5)
