"""Static-analysis subsystem tests (repro.analysis).

Three legs:

* corruption-detection regressions — seed one structural corruption per
  format container (OOB uint16 col, non-bijective perm, stale fill_plan,
  duplicate y-push row, ...) and assert ``verify``/``verify_plan`` reports
  the *exact* rule;
* clean-pass sweep — all registered formats × a representative slice of
  the standard matrix suite produce zero findings (no false positives);
* the jaxpr sanitizer and source lint on synthetic programs/snippets, plus
  the repo's own source as a self-check.
"""

import dataclasses

import numpy as np
import pytest

from repro.analysis import Finding, errors, summarize, verify, verify_plan
from repro.analysis.invariants import check_halo_plan
from repro.analysis.jaxpr_lint import _probe_matrix, lint_jaxpr
from repro.analysis.source_lint import lint_source, run_source_lint
from repro.core import SUITE, build_ehyb
from repro.core.ehyb import build_buckets, pack_staircase
from repro.core.matrices import from_coo
from repro.dist.halo import build_halo_plan


def rules_of(findings):
    return {f.rule for f in findings}


@pytest.fixture(scope="module")
def m():
    return _probe_matrix()


@pytest.fixture(scope="module")
def e(m):
    return build_ehyb(m, n_parts=4, vec_size=16)


# ---------------------------------------------------------------------------
# findings record
# ---------------------------------------------------------------------------

def test_finding_record():
    f = Finding("error", "EHYB.ell_cols", "index-bound.ell-local", "boom")
    assert "index-bound.ell-local" in str(f) and "[error]" in str(f)
    with pytest.raises(ValueError):
        Finding("fatal", "x", "r", "m")
    fs = [f, Finding("warning", "y", "bf16-accum", "w"),
          Finding("info", "z", "note", "n")]
    assert errors(fs) == [f]
    assert summarize(fs) == {"bf16-accum": 1, "index-bound.ell-local": 1,
                             "note": 1}


# ---------------------------------------------------------------------------
# corruption regressions: host EHYB family
# ---------------------------------------------------------------------------

def test_detects_oob_uint16_col(e):
    bad = dataclasses.replace(e, ell_cols=e.ell_cols.copy())
    bad.ell_cols[0, 0, 0] = e.vec_size          # one past the tile edge
    assert "index-bound.ell-local" in rules_of(verify(bad))


def test_detects_oob_er_global_col(e):
    bad = dataclasses.replace(e, er_cols=e.er_cols.copy())
    assert bad.er_cols.size, "probe matrix must have ER rows"
    bad.er_cols.reshape(-1)[0] = e.n_pad
    assert "index-bound.er-global" in rules_of(verify(bad))


def test_detects_non_bijective_perm(e):
    p = e.perm.copy()
    p[1] = p[0]
    assert "perm-bijection" in rules_of(verify(dataclasses.replace(e,
                                                                   perm=p)))


def test_detects_swapped_inverse(e):
    # both are bijections but not mutual inverses
    q = np.roll(e.inv_perm, 1)
    assert "perm-bijection" in rules_of(
        verify(dataclasses.replace(e, inv_perm=q)))


def test_detects_stale_fill_plan(e):
    fp = dict(e.fill_plan)
    fp["ell_src"] = fp["ell_src"].copy()
    fp["ell_src"][0] = fp["ell_src"][1]         # entry duplicated, one lost
    assert "fill-plan-bijection" in rules_of(
        verify(dataclasses.replace(e, fill_plan=fp)))


def test_detects_duplicate_fill_dst(e):
    fp = dict(e.fill_plan)
    fp["ell_dst"] = fp["ell_dst"].copy()
    fp["ell_dst"][0] = fp["ell_dst"][1]
    assert "fill-plan-bijection" in rules_of(
        verify(dataclasses.replace(e, fill_plan=fp)))


def test_detects_padding_violation(e):
    ev = e.ell_vals.copy()
    ev[-1, -1, -1] = 7.0                        # dead slot made nonzero
    assert "padding-sentinel" in rules_of(
        verify(dataclasses.replace(e, ell_vals=ev)))


def test_detects_width_tampering(e):
    pw = e.part_widths.copy()
    pw[0] += 1
    assert "width-consistency" in rules_of(
        verify(dataclasses.replace(e, part_widths=pw)))


def test_detects_nonfinite_values(e):
    ev = e.ell_vals.copy()
    live = np.argwhere(ev != 0)[0]
    ev[tuple(live)] = np.nan
    assert "value-finite" in rules_of(
        verify(dataclasses.replace(e, ell_vals=ev)))


def test_detects_broken_staircase(e):
    pk = pack_staircase(e)
    cr = pk.col_rows.copy()
    p = int(np.argmax(cr[:, 0] >= 2))
    cr[p, 0], cr[p, 1] = cr[p, 1], cr[p, 0] + 1  # widths increase in k
    cs = np.zeros_like(pk.col_starts)
    cs[:, 1:] = np.cumsum(cr, axis=1)            # keep starts consistent
    bad = dataclasses.replace(pk, col_rows=cr, col_starts=cs)
    assert "staircase-monotone" in rules_of(verify(bad))


def test_detects_bucket_cover_violation(e):
    b = build_buckets(e)
    ids = [c.copy() for c in b.part_ids]
    donor = next(i for i, c in enumerate(ids) if len(c))
    ids[donor][0] = ids[donor][-1] if len(ids[donor]) > 1 else \
        (ids[donor][0] + 1) % e.n_parts
    bad = dataclasses.replace(b, part_ids=ids)
    assert "bucket-cover" in rules_of(verify(bad))


# ---------------------------------------------------------------------------
# corruption regressions: device containers (all 7 registered formats)
# ---------------------------------------------------------------------------

def _built(fmt, m):
    from repro.autotune import build_format

    obj, _ = build_format(fmt, m, shared={})
    return obj


def test_detects_stream_oob_csr(m):
    import jax.numpy as jnp

    d = _built("csr", m)
    bad = dataclasses.replace(d, cols=jnp.asarray(d.cols).at[0].set(m.n))
    assert "index-bound.stream" in rules_of(verify(bad))


def test_detects_stream_oob_ell(m):
    import jax.numpy as jnp

    d = _built("ell", m)
    bad = dataclasses.replace(d, cols=jnp.asarray(d.cols).at[0, 0].set(-1))
    assert "index-bound.stream" in rules_of(verify(bad))


def test_detects_stream_oob_hyb(m):
    import jax.numpy as jnp

    d = _built("hyb", m)
    bad = dataclasses.replace(
        d, coo_rows=jnp.asarray(d.coo_rows).at[0].set(m.n))
    assert "index-bound.stream" in rules_of(verify(bad))


def test_detects_device_ehyb_oob(m):
    import jax.numpy as jnp

    d = _built("ehyb", m)
    bad = dataclasses.replace(
        d, ell_cols=jnp.asarray(d.ell_cols).at[0, 0, 0].set(d.vec_size))
    assert "index-bound.ell-local" in rules_of(verify(bad))
    bad2 = dataclasses.replace(
        d, er_p_rows=jnp.asarray(d.er_p_rows).at[0, 0].set(d.vec_size))
    assert "index-bound.er-global" in rules_of(verify(bad2))


def test_detects_device_packed_corruption(m):
    import jax.numpy as jnp

    d = _built("ehyb_packed", m)
    bad = dataclasses.replace(
        d, packed_cols=jnp.asarray(d.packed_cols).at[0, 0].set(d.vec_size))
    assert "index-bound.ell-local" in rules_of(verify(bad))


def test_detects_device_buckets_corruption(m):
    import jax.numpy as jnp

    d = _built("ehyb_bucketed", m)
    ids = tuple(jnp.asarray(c) for c in d.part_ids)
    donor = next(i for i, c in enumerate(ids) if c.size)
    repl = ids[donor].at[0].set(int(ids[donor][-1]) if ids[donor].size > 1
                                else (int(ids[donor][0]) + 1) % d.n_parts)
    bad = dataclasses.replace(
        d, part_ids=ids[:donor] + (repl,) + ids[donor + 1:])
    assert "bucket-cover" in rules_of(verify(bad))


def test_detects_dense_corruption(m):
    import jax.numpy as jnp

    d = _built("dense", m)
    assert "value-finite" in rules_of(
        verify(d.at[0, 0].set(jnp.nan)))
    assert "width-consistency" in rules_of(verify(d[:, :-1]))


# ---------------------------------------------------------------------------
# corruption regressions: halo plan conservation laws
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hp(e):
    return build_halo_plan(e, 4)


def test_halo_plan_clean(hp, e):
    assert check_halo_plan(hp, e) == []


def test_detects_duplicate_push_row(hp, e):
    d = next(d for d in range(hp.n_dev) if hp.counts_push[d].sum() >= 2)
    rr = hp.rp_rows.copy()
    rr[d, 1] = rr[d, 0]                 # two scatter-adds on one row
    bad = dataclasses.replace(hp, rp_rows=rr)
    assert "halo-push-race" in rules_of(check_halo_plan(bad, e))


def test_detects_word_accounting_drift(hp, e):
    bad = dataclasses.replace(hp, halo_words=hp.halo_words + 1)
    assert rules_of(check_halo_plan(bad, e)) == {"halo-accounting"}


def test_detects_dropped_coverage(hp, e):
    assert len(hp.fer_src), "probe matrix must have fetch-side entries"
    bad = dataclasses.replace(hp, fer_src=hp.fer_src[:-1],
                              fer_dst=hp.fer_dst[:-1])
    assert "halo-coverage" in rules_of(check_halo_plan(bad, e))


def test_detects_tampered_send_schedule(hp, e):
    pair = np.argwhere((np.asarray(hp.direction) == 1)
                       & (np.asarray(hp.counts_fetch) > 0))
    assert len(pair), "probe matrix must have fetch pairs"
    d, s = pair[0]
    si = hp.send_idx.copy()
    si[s, d, 0] += 1                    # fetch the wrong column
    bad = dataclasses.replace(hp, send_idx=si)
    assert "halo-coverage" in rules_of(check_halo_plan(bad, e))


def test_halo_plan_without_source_is_info_only(hp):
    fs = check_halo_plan(hp)
    assert errors(fs) == []
    assert any(f.severity == "info" for f in fs)


# ---------------------------------------------------------------------------
# clean-pass sweep: zero false positives over formats × suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["poisson3d_16", "unstruct_4k",
                                  "powerlaw_4k"])
def test_clean_sweep_suite(name):
    from repro.autotune import available_formats, build_format

    from repro.autotune.registry import shared_ehyb

    mat = SUITE[name]()
    shared = {}
    e = shared_ehyb(mat, shared)    # one family-wide host build
    for fmt in available_formats():
        obj, _ = build_format(fmt, mat, shared=shared)
        assert verify(obj) == [], f"false positive: {fmt} on {name}"
    for n_dev in (2, 4):
        assert check_halo_plan(build_halo_plan(e, n_dev), e) == []


def test_operator_and_plan_verify_clean(m):
    import repro.api as api
    from repro.api.config import ExecutionConfig
    from repro.autotune import available_formats

    for fmt in available_formats():
        p = api.plan(m, execution=ExecutionConfig(format=fmt))
        op = p.bind(m.data, validate="full")    # raises on error findings
        assert verify(op) == []
        assert verify_plan(p) == []


def test_bind_full_rejects_corrupt_container(m, monkeypatch):
    import repro.api as api
    from repro.api.config import ExecutionConfig
    from repro.autotune import FORMATS

    p = api.plan(m, execution=ExecutionConfig(format="ehyb"))
    spec = FORMATS["ehyb"]          # frozen: swap the registry entry
    monkeypatch.setitem(
        FORMATS, "ehyb", dataclasses.replace(
            spec, invariants=lambda obj: [
                Finding("error", "EHYBDevice", "perm-bijection",
                        "seeded")]))
    with pytest.raises(ValueError, match="perm-bijection"):
        p.bind(m.data, validate="full")
    # default bind keeps only the cheap checks — unaffected by the hook
    p.bind(m.data)


# ---------------------------------------------------------------------------
# jaxpr sanitizer
# ---------------------------------------------------------------------------

def test_jaxpr_flags_host_callback():
    import jax
    import jax.numpy as jnp

    def f(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct(x.shape, x.dtype),
            x)

    closed = jax.make_jaxpr(f)(jnp.zeros(4))
    assert "host-callback" in rules_of(lint_jaxpr(closed, "t"))


def test_jaxpr_flags_bf16_accumulation():
    import jax
    import jax.numpy as jnp

    closed = jax.make_jaxpr(lambda a, b: a @ b)(
        jnp.zeros((4, 4), jnp.bfloat16), jnp.zeros((4, 4), jnp.bfloat16))
    fs = lint_jaxpr(closed, "t")
    assert "bf16-accum" in rules_of(fs)
    assert all(f.severity == "warning" for f in fs)


def test_jaxpr_accepts_f32_accumulation():
    import jax
    import jax.numpy as jnp

    def f(a, b):
        return jnp.dot(a, b, preferred_element_type=jnp.float32)

    closed = jax.make_jaxpr(f)(jnp.zeros((4, 4), jnp.bfloat16),
                               jnp.zeros((4, 4), jnp.bfloat16))
    assert lint_jaxpr(closed, "t") == []


def test_jaxpr_flags_oversized_const():
    import jax
    import jax.numpy as jnp

    big = jnp.zeros((64, 1024))                 # 256 KiB closure constant
    closed = jax.make_jaxpr(lambda x: x + big)(jnp.zeros((64, 1024)))
    assert "oversized-const" in rules_of(lint_jaxpr(closed, "t"))


def test_jaxpr_sweep_registered_formats_has_no_errors():
    from repro.analysis.jaxpr_lint import run_jaxpr_lint

    fs = run_jaxpr_lint(formats=["ehyb", "ehyb_packed"],
                        with_sharded=False)
    assert errors(fs) == []                     # warnings ride the baseline


# ---------------------------------------------------------------------------
# source lint
# ---------------------------------------------------------------------------

def test_lint_broad_except():
    src = ("try:\n    pass\n"
           "except Exception:\n    pass\n")
    assert rules_of(lint_source(src, "t.py")) == {"BLE001"}
    tagged = ("try:\n    pass\n"
              "except Exception:  # noqa: BLE001 — probe\n    pass\n")
    assert lint_source(tagged, "t.py") == []


def test_lint_bare_except_never_taggable():
    src = ("try:\n    pass\n"
           "except:  # noqa: BLE002\n    pass\n")
    assert rules_of(lint_source(src, "t.py")) == {"BLE002"}
    src2 = ("try:\n    pass\n"
            "except BaseException:\n    raise\n")
    assert rules_of(lint_source(src2, "t.py")) == {"BLE002"}


def test_lint_module_scope_jnp():
    src = ("import jax.numpy as jnp\n"
           "TABLE = jnp.arange(8)\n")
    assert rules_of(lint_source(src, "t.py")) == {"JNP001"}
    ok = ("import jax.numpy as jnp\n"
          "def f():\n    return jnp.arange(8)\n")
    assert lint_source(ok, "t.py") == []


def test_lint_deprecated_shims():
    src = "from repro.core.spmv import build_spmv\n"
    assert rules_of(lint_source(src, "t.py", "repro.other")) == {"DEP001"}
    src2 = "from repro.core import dist_spmv\n"
    assert rules_of(lint_source(src2, "t.py", "repro.other")) == {"DEP001"}
    # the defining module itself is exempt
    assert lint_source(src, "t.py", "repro.core.spmv") == []


def test_lint_unhashable_pytree_aux():
    src = ("class C:\n"
           "    def tree_flatten(self):\n"
           "        return (self.x,), [self.n]\n")
    assert rules_of(lint_source(src, "t.py")) == {"PYT001"}
    ok = ("class C:\n"
          "    def tree_flatten(self):\n"
          "        aux = (self.n, self.widths)\n"
          "        return (self.x,), aux\n")
    assert lint_source(ok, "t.py") == []


def test_lint_wallclock_under_jit():
    src = ("import time\nimport jax\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    t = time.perf_counter()\n"
           "    return x + t\n")
    assert rules_of(lint_source(src, "t.py")) == {"JIT001"}
    ok = ("import time\n"
          "def g(x):\n"
          "    return time.perf_counter()\n")
    assert lint_source(ok, "t.py") == []


def test_repo_source_is_lint_clean():
    """The committed source baseline is empty: src/ + benchmarks/ carry no
    untagged broad excepts, module-scope jnp work, deprecated-shim use,
    unhashable pytree aux, or wall-clock-under-jit."""
    assert run_source_lint() == []
