"""Reliability layer: guarded apply, solver guardrails, serving admission
control — every recovery path driven by deterministic fault injection
(``repro.reliability.chaos``), asserting both that the fault actually fired
and that the system degraded gracefully instead of crashing or silently
corrupting results."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecutionConfig, plan
from repro.core import counters
from repro.core.matrices import SparseCSR, poisson3d, unstructured
from repro.core.solver import PRECONDITIONERS, SolveResult, bicgstab, cg
from repro.reliability import (EnginePolicy, ReliabilityWarning,
                               SolveFailure, SolveFailureWarning,
                               SolvePolicy, chaos, flood)
from repro.reliability.guard import reset_warned


@pytest.fixture(autouse=True)
def _quiet_reliability_warnings():
    """These tests trigger degradations on purpose; assertions use counters
    and statuses, not warning capture (except where pytest.warns is the
    point)."""
    reset_warned()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", ReliabilityWarning)
        yield


def _dense_mv(m):
    a = jnp.asarray(m.to_dense(), jnp.float32)
    return lambda v: a @ v


# ---------------------------------------------------------------------------
# guarded apply: fallback chain + recovery
# ---------------------------------------------------------------------------

class TestGuardedApply:
    def test_native_failure_falls_back_to_unfused(self, rng):
        m = unstructured(256, 8, seed=31)
        p = plan(m, execution=ExecutionConfig(format="ehyb_packed"))
        op = p.bind(m)
        x = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        want = m.to_dense() @ np.asarray(x, np.float64)
        with chaos(kernel_failure=("ehyb_packed:native",)) as cfg:
            y = np.asarray(op @ x, np.float64)
            assert p.degraded == {"apply": "ehyb_packed:unfused"}
        assert cfg.injected["kernel:ehyb_packed:native"] >= 1
        np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)

    def test_all_pallas_failure_falls_back_to_reference(self, rng):
        m = unstructured(256, 8, seed=32)
        p = plan(m, execution=ExecutionConfig(format="ehyb_packed"))
        op = p.bind(m)
        x = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        want = m.to_dense() @ np.asarray(x, np.float64)
        before = counters.snapshot()
        with chaos(kernel_failure=("ehyb_packed:*",)) as cfg:
            y = np.asarray(op @ x, np.float64)
            assert p.degraded == {"apply": "reference"}
        assert cfg.injected["kernel:ehyb_packed:native"] >= 1
        assert cfg.injected["kernel:ehyb_packed:unfused"] >= 1
        after = counters.snapshot()
        assert after.get("guard.downgrade", 0) > before.get(
            "guard.downgrade", 0)
        assert after.get("guard.downgrade.ehyb_packed", 0) > before.get(
            "guard.downgrade.ehyb_packed", 0)
        np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)

    def test_guard_recovers_native_after_chaos_exits(self, rng):
        m = unstructured(256, 8, seed=33)
        p = plan(m, execution=ExecutionConfig(format="ehyb_packed"))
        op = p.bind(m)
        x = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        with chaos(kernel_failure=("ehyb_packed:*",)):
            np.asarray(op @ x)
            assert p.degraded
        # epoch moved on exit: the next dispatch re-resolves to native
        want = m.to_dense() @ np.asarray(x, np.float64)
        y = np.asarray(op @ x, np.float64)
        assert p.degraded == {}
        np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)

    def test_guarded_solve_converges_on_reference(self, rng):
        """Tentpole acceptance: a forced Pallas lowering failure must leave
        solve() working through the fallback chain, conformant with the
        dense oracle."""
        m = poisson3d(8)
        p = plan(m, execution=ExecutionConfig(format="ehyb_packed",
                                              workload="solver"))
        op = p.bind(m)
        b = rng.standard_normal(m.n).astype(np.float32)
        with chaos(kernel_failure=("ehyb_packed:*",)) as cfg:
            r = op.solve(jnp.asarray(b), tol=1e-5)
            assert p.degraded.get("permuted") == "reference"
        assert cfg.injected
        assert r.status == "converged"
        ax = m.spmv(np.asarray(r.x, np.float64))
        assert np.linalg.norm(ax - b) / np.linalg.norm(b) < 1e-4

    def test_backend_probe_failure_disables_pallas_levels(self, rng):
        from repro.kernels.ops import backend_supports_pallas

        assert backend_supports_pallas()      # healthy CPU interpreter
        m = unstructured(128, 6, seed=34)
        p = plan(m, execution=ExecutionConfig(format="ehyb_packed"))
        op = p.bind(m)
        x = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        want = m.to_dense() @ np.asarray(x, np.float64)
        with chaos(kernel_failure=("pallas:probe",)):
            assert not backend_supports_pallas()
            y = np.asarray(op @ x, np.float64)
            assert p.degraded == {"apply": "reference"}
        np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)
        assert backend_supports_pallas()      # re-probed after the epoch


# ---------------------------------------------------------------------------
# autotuner: a failing measured candidate is skipped, not fatal
# ---------------------------------------------------------------------------

def test_tuner_skips_failing_measured_candidate():
    from repro import autotune as at

    m = unstructured(192, 7, seed=35)
    before = counters.snapshot()
    with chaos(kernel_failure=("tune:ell",)) as cfg:
        t = at.autotune(m, mode="measure", candidates=("csr", "ell", "hyb"))
    assert cfg.injected["kernel:tune:ell"] == 1
    assert "ell" not in (t.measured_s or {})
    assert t.format in ("csr", "hyb")
    after = counters.snapshot()
    assert after.get("tune.candidate_failed", 0) == \
        before.get("tune.candidate_failed", 0) + 1
    # the ranking decided under chaos must not have been cached
    t2 = at.autotune(m, mode="measure", candidates=("csr", "ell", "hyb"))
    assert "ell" in t2.measured_s


# ---------------------------------------------------------------------------
# solver guardrails (satellites 1 + 2 + stagnation)
# ---------------------------------------------------------------------------

class TestSolverGuardrails:
    def test_bicgstab_breakdown_detected_not_masked(self):
        """Regression (satellite 1): on A = [[0,1],[-1,0]], b = [1,0] the
        shadow-residual dot r̂·v is exactly zero at the first step.  The old
        code clamped the denominator to 1e-30 and kept iterating on a dead
        recurrence (alpha ~ 1e30: garbage iterates); the rewrite must stop
        with status "breakdown" and a finite iterate."""
        a = jnp.asarray([[0.0, 1.0], [-1.0, 0.0]], jnp.float32)
        b = jnp.asarray([1.0, 0.0], jnp.float32)
        r = bicgstab(lambda v: a @ v, b, tol=1e-8, max_iters=50)
        assert r.status == "breakdown"
        assert not bool(r.converged)
        assert np.isfinite(np.asarray(r.x)).all()
        assert np.isfinite(float(r.residual))

    def test_cg_breakdown_on_indefinite_operator(self):
        a = jnp.asarray(np.diag([1.0, -1.0]), jnp.float32)
        b = jnp.asarray([1.0, 1.0], jnp.float32)
        r = cg(lambda v: a @ v, b, tol=1e-8, max_iters=50)
        assert r.status == "breakdown"
        assert not bool(r.converged)
        assert np.isfinite(np.asarray(r.x)).all()

    def test_nan_matvec_classified_diverged(self):
        b = jnp.ones((8,), jnp.float32)
        bad = lambda v: jnp.full_like(v, jnp.nan)      # noqa: E731
        assert cg(bad, b, max_iters=5).status == "diverged"
        assert bicgstab(bad, b, max_iters=5).status == "diverged"
        # the rolled-back iterate stays finite either way
        assert np.isfinite(np.asarray(cg(bad, b, max_iters=5).x)).all()

    def test_stagnation_detected_at_unreachable_tol(self, rng):
        m = poisson3d(8)
        b = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        r = cg(_dense_mv(m), b, PRECONDITIONERS["jacobi"](m), tol=1e-30,
               max_iters=2000, stag_window=25, stag_rtol=0.05)
        assert r.status == "stagnated"
        assert int(r.iters) < 2000
        # the kept iterate is still the (machine-precision) solution
        ax = m.spmv(np.asarray(r.x, np.float64))
        assert np.linalg.norm(ax - np.asarray(b)) / \
            np.linalg.norm(np.asarray(b)) < 1e-4

    def test_healthy_trajectories_unchanged(self, rng):
        """Guardrails are branch-free selects: a converging solve must take
        exactly the iterates it always took."""
        m = poisson3d(8)
        b = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        r = cg(_dense_mv(m), b, PRECONDITIONERS["jacobi"](m), tol=1e-5,
               max_iters=1000)
        assert r.status == "converged" and bool(r.converged)
        m2 = unstructured(512, 10, seed=9)
        b2 = jnp.asarray(rng.standard_normal(m2.n), jnp.float32)
        r2 = bicgstab(_dense_mv(m2), b2, PRECONDITIONERS["jacobi"](m2),
                      tol=1e-5, max_iters=1000)
        assert r2.status == "converged" and bool(r2.converged)

    def test_status_property_backfills_legacy_results(self):
        r = SolveResult(x=jnp.zeros(2), iters=jnp.int32(3),
                        residual=jnp.float32(0.5),
                        converged=jnp.asarray(False))
        assert r.status == "maxiter"          # status_code defaults to None


class TestSolveFailureReporting:
    def test_maxiter_warns_structured(self, rng):
        """Satellite 2: a solve that returns non-converged must say so."""
        m = poisson3d(8)
        p = plan(m, execution=ExecutionConfig(format="ehyb",
                                              workload="solver"))
        op = p.bind(m)
        b = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        with pytest.warns(SolveFailureWarning, match="maxiter"):
            r = op.solve(b, tol=1e-10, max_iters=1)
        assert r.status == "maxiter" and not bool(r.converged)

    def test_raise_on_failure_carries_result(self, rng):
        m = poisson3d(8)
        p = plan(m, execution=ExecutionConfig(format="ehyb",
                                              workload="solver"))
        op = p.bind(m)
        b = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        with pytest.raises(SolveFailure) as ei:
            op.solve(b, tol=1e-10, max_iters=1, raise_on_failure=True)
        assert ei.value.result is not None
        assert ei.value.result.status == "maxiter"

    def test_nan_chaos_escalates_to_reference(self, rng):
        """Tentpole acceptance: silent kernel corruption (all-NaN applies)
        must be survived by the policy ladder — the reference re-run
        bypasses the corrupted kernel path and converges."""
        m = poisson3d(8)
        p = plan(m, execution=ExecutionConfig(format="ehyb",
                                              workload="solver"))
        op = p.bind(m)
        b = rng.standard_normal(m.n).astype(np.float32)
        before = counters.snapshot()
        with chaos(nan_apply=True) as cfg:
            r = op.solve(jnp.asarray(b), tol=1e-5, policy=SolvePolicy())
        assert cfg.injected["nan"] >= 1
        assert r.status == "converged"
        ax = m.spmv(np.asarray(r.x, np.float64))
        assert np.linalg.norm(ax - b) / np.linalg.norm(b) < 1e-4
        after = counters.snapshot()
        assert after.get("solver.escalate_reference", 0) > \
            before.get("solver.escalate_reference", 0)
        assert after.get("solver.recovered", 0) > \
            before.get("solver.recovered", 0)

    def test_policy_stagnation_status_without_escalation(self, rng):
        m = poisson3d(8)
        p = plan(m, execution=ExecutionConfig(format="ehyb",
                                              workload="solver"))
        op = p.bind(m)
        b = jnp.asarray(rng.standard_normal(m.n), jnp.float32)
        pol = SolvePolicy(max_restarts=0, escalate_method=False,
                          escalate_reference=False, stagnation_window=25,
                          stagnation_rtol=0.05)
        with pytest.warns(SolveFailureWarning, match="stagnated"):
            r = op.solve(b, tol=1e-30, max_iters=2000, policy=pol)
        assert r.status == "stagnated"


# ---------------------------------------------------------------------------
# bind-time validation (satellite 3)
# ---------------------------------------------------------------------------

class TestBindValidation:
    def _plan(self):
        m = unstructured(64, 5, seed=36)
        return m, plan(m, execution=ExecutionConfig(format="csr"))

    def test_nan_values_rejected(self):
        m, p = self._plan()
        vals = np.asarray(m.data, np.float64).copy()
        vals[1] = np.nan
        with pytest.raises(ValueError, match="non-finite"):
            p.bind(vals)

    def test_inf_values_rejected(self):
        m, p = self._plan()
        vals = np.asarray(m.data, np.float64).copy()
        vals[-1] = np.inf
        with pytest.raises(ValueError, match="non-finite"):
            p.bind(vals)

    def test_out_of_range_index_rejected(self):
        indptr = np.asarray([0, 1, 2], np.int64)
        indices = np.asarray([0, 7], np.int64)     # 7 >= n=2
        bad = SparseCSR(2, indptr, indices, np.asarray([1.0, 1.0]))
        p = plan(bad, execution=ExecutionConfig(format="csr"))
        with pytest.raises(ValueError, match="column indices outside"):
            p.bind(bad)

    def test_validate_false_opts_out(self):
        m, p = self._plan()
        vals = np.asarray(m.data, np.float64).copy()
        vals[1] = np.nan
        op = p.bind(vals, validate=False)
        assert op is not None                      # caller's poison, kept


# ---------------------------------------------------------------------------
# serving: admission control, deadlines, overload, chaos recovery
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import get_config
    from repro.models import init_model

    cfg = get_config("llama3_2_1b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _engine(serve_setup, **kw):
    from repro.serve import ServeEngine

    params, cfg = serve_setup
    kw.setdefault("batch", 1)
    kw.setdefault("max_len", 48)
    kw.setdefault("max_prompt", 8)
    return ServeEngine(params, cfg, **kw)


class TestServeAdmissionControl:
    def test_queue_flood_rejects_excess_and_finishes_admitted(
            self, serve_setup):
        """Tentpole acceptance: under a flood, excess requests are rejected
        with a reason and every admitted request finishes with its exact
        token count."""
        eng = _engine(serve_setup, max_queue=2)
        reqs = flood(eng, 6, max_new_tokens=3)
        rejected = [r for r in reqs if r.reject_reason == "queue_full"]
        admitted = [r for r in reqs if r.reject_reason is None]
        assert len(rejected) == 4 and len(admitted) == 2
        assert all(r.done for r in rejected)
        assert eng.health()["stats"]["rejected_queue_full"] == 4
        done = eng.run_until_done()
        finished = [r for r in done if r.reject_reason is None]
        assert sorted(r.uid for r in finished) == \
            sorted(r.uid for r in admitted)
        assert all(len(r.generated) == 3 for r in finished)

    def test_deadline_expires_queued_and_admitted(self, serve_setup):
        from repro.serve import Request

        t = [0.0]
        eng = _engine(serve_setup, clock=lambda: t[0],
                      policy=EnginePolicy(default_ttl_s=10.0))
        for i in range(3):
            eng.submit(Request(uid=i, prompt=np.arange(1, 5, dtype=np.int32),
                               max_new_tokens=6))
        done = eng.step()               # admits uid 0 into the single slot
        assert not done
        t[0] = 11.0                     # past every deadline
        done = eng.step()
        expired = {r.uid: r for r in done if r.reject_reason == "deadline"}
        assert sorted(expired) == [0, 1, 2]
        assert expired[0].generated     # admitted one keeps partial tokens
        stats = eng.health()["stats"]
        assert stats["expired_active"] == 1 and stats["expired_queued"] == 2

    def test_per_request_ttl_overrides_policy(self, serve_setup):
        from repro.serve import Request

        t = [0.0]
        eng = _engine(serve_setup, clock=lambda: t[0],
                      policy=EnginePolicy(default_ttl_s=1.0))
        eng.submit(Request(uid=0, prompt=np.arange(1, 5, dtype=np.int32),
                           max_new_tokens=2, ttl_s=100.0))
        t[0] = 5.0                      # past policy ttl, inside request ttl
        done = eng.run_until_done()
        assert len(done) == 1 and done[0].reject_reason is None
        assert len(done[0].generated) == 2

    def test_transient_apply_failure_retries_through(self, serve_setup):
        from repro.serve import Request

        eng = _engine(serve_setup)
        eng.submit(Request(uid=0, prompt=np.arange(1, 5, dtype=np.int32),
                           max_new_tokens=3))
        with chaos(serve_apply_failures=2) as cfg:
            done = eng.run_until_done()
        assert cfg.injected["serve:transient"] == 2
        assert len(done) == 1 and len(done[0].generated) == 3
        assert eng.stats["retries"] >= 2
        assert not eng.degraded         # transient: no degradation needed

    def test_sparse_head_failure_degrades_to_dense(self, serve_setup):
        """Tentpole acceptance: a persistently failing sparse head must not
        drop admitted requests — the engine degrades to the dense path and
        produces exactly what a dense engine would."""
        from repro.serve import Request

        prompt = np.arange(1, 7, dtype=np.int32)
        ref = _engine(serve_setup)
        ref.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
        want = ref.run_until_done()[0].generated

        eng = _engine(serve_setup, sparse_head_density=1.0)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
        before = counters.snapshot()
        with chaos(fail_sparse_apply=True) as cfg:
            done = eng.run_until_done()
        assert cfg.injected["serve:sparse"] >= 1
        assert eng.degraded and eng.health()["degraded"]
        assert len(done) == 1 and done[0].generated == want
        after = counters.snapshot()
        assert after.get("serve.degraded", 0) == \
            before.get("serve.degraded", 0) + 1
        # the sparse layer survives: restore swaps it back in
        eng.restore_sparse_head()
        assert not eng.degraded
        eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=2))
        done2 = eng.run_until_done()
        assert len(done2) == 1 and len(done2[0].generated) == 2

    def test_health_snapshot_shape(self, serve_setup):
        eng = _engine(serve_setup, max_queue=4)
        h = eng.health()
        assert h["queue_depth"] == 0 and h["active"] == 0
        assert h["max_queue"] == 4 and h["degraded"] is False
        assert isinstance(h["stats"], dict)
